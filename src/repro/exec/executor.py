"""The resumable workflow executor: run a DAG for real, twin to the sim.

:class:`WorkflowExecutor` takes the SAME :class:`~repro.sim.workflow.Stage`
DAG the simulator runs, binds each stage to a real
:class:`~repro.exec.tasks.StageTask`, and executes stages in topological
order under a pinned :class:`~repro.runtime.failures.WorkflowSchedule` —
the serialized churn realization the sim predicts against.  A schedule
built with a ``mix``/``store`` carries each stage's class map and replica-
holder realization, and the stages then run heterogeneous (supersteps at
class speed, hazard-weighted estimator exposure) with endogenous restore
and hand-off latency read off the pinned holders — one cycle-accounting
core shared with the sim's closed-form law.  Every stage persists through
its own :class:`~repro.ckpt.async_ckpt.AsyncCheckpointer` over per-stage
primary + neighbour directories (HRW placement, corrupt-primary fallback),
and the resume protocol is just "reopen the executor with
``resume=True``": each stage restores from the newest surviving replica, a
stage whose committed step already covers its supersteps is skipped, and
execution continues from exactly the last durable superstep.

Typical crash-and-resume round trip::

    ex = WorkflowExecutor(spec, tasks, schedule, cfg)
    try:
        ex.run(kill=KillSpec("train", after_supersteps=25))
    except ExecutorKilled:
        pass                       # the 'process' died mid-superstep
    report = WorkflowExecutor(spec, tasks, schedule, cfg).run(resume=True)
"""
from __future__ import annotations

import time
from typing import Any, Dict, Mapping, Optional

from repro.ckpt.async_ckpt import AsyncCheckpointer
from repro.exec.state import (
    ExecReport,
    ExecutorConfig,
    KillSpec,
    stage_paths,
)
from repro.exec.superstep import run_stage
from repro.exec.tasks import StageTask
from repro.runtime.failures import WorkflowSchedule
from repro.sim.workflow import WorkflowSpec


class WorkflowExecutor:
    """Execute a workflow DAG as real superstep-checkpointed work units.

    One instance models one *incarnation* of the executor process: ``run``
    walks the DAG once, and an injected :class:`KillSpec` terminates the
    incarnation by raising :class:`~repro.exec.state.ExecutorKilled`.  A
    fresh instance over the same ``cfg.root`` with ``resume=True`` picks
    up from the durable state — the paper's recover-from-P2P-storage path.
    """

    def __init__(
        self,
        spec: WorkflowSpec,
        tasks: Mapping[str, StageTask],
        schedule: WorkflowSchedule,
        cfg: ExecutorConfig,
    ):
        missing_tasks = {s.name for s in spec.stages} - set(tasks)
        if missing_tasks:
            raise ValueError(f"no task bound for stages {sorted(missing_tasks)}")
        missing_sched = {s.name for s in spec.stages} - set(schedule.stages)
        if missing_sched:
            raise ValueError(f"no schedule for stages {sorted(missing_sched)}")
        for s in spec.stages:
            if schedule.stages[s.name].k != s.k:
                raise ValueError(
                    f"stage {s.name!r}: schedule was built for "
                    f"k={schedule.stages[s.name].k}, spec has k={s.k}")
        self.spec = spec
        self.tasks = dict(tasks)
        self.schedule = schedule
        self.cfg = cfg

    # ------------------------------------------------------------------ #
    def run(self, *, resume: bool = False,
            kill: Optional[KillSpec] = None) -> ExecReport:
        """Execute (or resume) the whole DAG.  Raises ExecutorKilled when
        ``kill`` fires; everything committed before the kill is durable."""
        cfg = self.cfg
        t_real0 = time.monotonic()
        report = ExecReport()
        payloads: Dict[str, Any] = {}
        finish: Dict[str, float] = {}
        ok: Dict[str, bool] = {}

        for stage in self.spec.topo_order():
            ready = max((finish[d] for d in stage.deps), default=0.0)
            if not all(ok[d] for d in stage.deps):
                # Censored dependency: this stage can never fetch its
                # inputs — mark unfinished, same containment rule as the sim.
                finish[stage.name] = ready
                ok[stage.name] = False
                continue
            paths = stage_paths(cfg.root, stage.name, cfg.n_replica_dirs)
            ckpt = AsyncCheckpointer(
                root=paths.primary, replicas=paths.replicas,
                n_shards=cfg.n_shards,
                replication_factor=cfg.replication_factor)
            try:
                srep, payload = run_stage(
                    stage, self.tasks[stage.name],
                    {d: payloads[d] for d in stage.deps},
                    self.schedule.stages[stage.name], ckpt, cfg,
                    resume=resume,
                    kill=kill if kill is not None and kill.stage == stage.name
                    else None,
                    real_t0=t_real0)
            finally:
                ckpt.close()
            elapsed = srep.finish  # stage-relative; rebase onto DAG clock
            srep.ready = ready
            srep.finish = ready + elapsed
            report.stages[stage.name] = srep
            finish[stage.name] = srep.finish
            ok[stage.name] = srep.completed
            if payload is not None:
                payloads[stage.name] = payload
            if resume and report.resume_latency_s is None \
                    and srep.first_step_real_s is not None:
                report.resume_latency_s = srep.first_step_real_s

        report.completed = bool(ok) and all(ok.values())
        report.makespan = max(finish.values(), default=0.0)
        report.real_seconds = time.monotonic() - t_real0
        return report

    # ------------------------------------------------------------------ #
    def output(self, stage: str, like: Any) -> Optional[Any]:
        """The committed output payload of ``stage`` (None if not durable)."""
        paths = stage_paths(self.cfg.root, stage, self.cfg.n_replica_dirs)
        ckpt = AsyncCheckpointer(
            root=paths.primary, replicas=paths.replicas,
            n_shards=self.cfg.n_shards,
            replication_factor=self.cfg.replication_factor)
        try:
            got = ckpt.restore_latest(like)
        finally:
            ckpt.close()
        return None if got is None else got[1]
