"""The checkpointable superstep loop of one stage (digital-twin mirror).

This loop is the executor-side image of :func:`repro.sim.job.simulate_job`
— same cycle semantics, same waste accounting — with the simulated state
replaced by a real :class:`~repro.exec.tasks.StageTask` payload and the
simulated storage by a real :class:`~repro.ckpt.async_ckpt.AsyncCheckpointer`:

* time advances on the injector's virtual clock; a stage's fault-free work
  is quantized into supersteps of ``cfg.seconds_per_superstep`` WORK units,
  each costing ``work / speed`` virtual seconds at the schedule's recorded
  class speed (``interval * speed`` work committed per cadence, exactly the
  engine's heterogeneous cycle law; speed is 1.0 for class-free schedules);
* before computing, each dependency's output is fetched under churn.
  Without a pinned store the edge costs ``stage.handoff`` flat virtual
  seconds; with one, the fetch reads the schedule's holder realization at
  the attempt's virtual time — striped over the surviving holders' class
  uplinks, server fallback (billed as server I/O per attempt) when all
  replicas are down — exactly the sim's `_handoff_times` law;
* a checkpoint is taken when the time since the last commit reaches the
  controller's live interval: ``V`` churn-exposed virtual seconds plus a
  real save (step number == superstep) replicated via HRW placement;
* a job failure rolls back: everything since the last commit is recompute
  waste, then restore time is paid (retried under churn).  With a pinned
  store the restore latency is *endogenous* — derived from the holders
  alive at that virtual instant in the schedule's realization, the same
  data the sim's closed-form survivor law models — otherwise the exogenous
  ``T_d`` applies as before.  The payload is reloaded from the newest
  *surviving* replica — a corrupt primary falls through to the neighbours;
* the final payload is persisted at step ``n_supersteps`` with no virtual
  cost (the sim's final cycle has no V either — the output transfer is
  billed on the consuming edge), marking the stage complete for the
  resume protocol.

Censoring mirrors the sim too: a stage that exceeds ``max_wall_factor``
times its fault-free wall time (hand-off and compute horizons separately)
is reported incomplete rather than spun on; a retry loop that instead
outlives the schedule's recorded horizon (:class:`~repro.runtime.failures.
ScheduleExhausted`) is reported censored the same way, flagged on the
report, rather than crashing the executor.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

from repro.ckpt.async_ckpt import AsyncCheckpointer
from repro.core.adaptive import AdaptiveCheckpointController
from repro.exec.state import ExecutorConfig, ExecutorKilled, KillSpec, StageExecReport
from repro.exec.tasks import StageTask
from repro.runtime.failures import (
    FailureInjector,
    ScheduleExhausted,
    SimulatedFailure,
    StageSchedule,
)
from repro.sim.workflow import Stage


def run_stage(
    stage: Stage,
    task: StageTask,
    dep_payloads: Dict[str, Any],
    schedule: StageSchedule,
    ckpt: AsyncCheckpointer,
    cfg: ExecutorConfig,
    *,
    resume: bool = False,
    kill: Optional[KillSpec] = None,
    real_t0: Optional[float] = None,
) -> Tuple[StageExecReport, Optional[Any]]:
    """Run (or resume) one stage to completion under the pinned schedule.

    Returns ``(report, payload)``; ``payload`` is None when the stage was
    censored.  ``report.finish`` holds the stage-relative elapsed virtual
    time (the caller rebases it onto the workflow clock).  An injected
    :class:`KillSpec` raises :class:`ExecutorKilled` mid-superstep.
    """
    speed = schedule.job_speed()
    n_super = max(int(round(stage.work / cfg.seconds_per_superstep)), 1)
    sps = stage.work / n_super  # exact: n_super supersteps == stage.work
    stage_wall = stage.work / speed
    V = stage.V if stage.V is not None else cfg.V
    T_d = stage.T_d if stage.T_d is not None else cfg.T_d
    endo = schedule.store is not None
    if endo:
        transfer = schedule.store.transfer
        img = transfer.img_bytes
        holders = schedule.holder_view()
        uplinks = schedule.holder_uplinks()
    inj = FailureInjector.from_schedule(schedule,
                                        seconds_per_step=sps / speed)
    ctl = AdaptiveCheckpointController(
        k=schedule.job_hazard_sum(), prior_mu=cfg.prior_mu, prior_v=V,
        mu_window=cfg.mu_window, min_interval=cfg.min_interval,
        max_interval=cfg.max_interval)
    rep = StageExecReport(name=stage.name, n_supersteps=n_super)

    def interval() -> float:
        if cfg.policy == "fixed":
            return cfg.fixed_interval
        return ctl.checkpoint_interval()

    def feed() -> None:
        # Watched-neighbourhood deaths -> the live estimator, the same
        # observation stream the sim's AdaptivePolicy consumes (the job's
        # own failure event is part of it: slot < k implies slot < watch).
        for lifetime in inj.drain_observations():
            ctl.observe_failure(lifetime)

    def censored() -> Tuple[StageExecReport, None]:
        rep.completed = False
        rep.final_interval = interval()
        rep.finish = inj.virtual_time
        return rep, None

    def fetch_cost() -> Tuple[float, bool]:
        # Endogenous transfer time at the current virtual instant: stripe
        # over the uplinks of the holders alive NOW in the pinned
        # realization; (server_seconds, True) when all replicas are down.
        alive: List[int] = holders.alive_slots(inj.virtual_time)
        td = transfer.restore_seconds_from([uplinks[i] for i in alive])
        return td, not alive

    like = task.init(dep_payloads)
    got = ckpt.restore_latest(like) if resume else None
    if got is not None and got[0] >= n_super:
        # A previous incarnation already committed the stage output.
        rep.start_superstep = rep.committed_superstep = n_super
        rep.completed = rep.resumed = True
        return rep, got[1]

    try:
        # -------------------------------------------------------------- #
        # Hand-off: fetch each dependency's output under churn.  Skipped #
        # on a mid-stage resume — the restored payload folds the deps in.#
        # -------------------------------------------------------------- #
        if got is None:
            edge_budget = schedule.store.td_server if endo else stage.handoff
            total_handoff = edge_budget * len(stage.deps)
            handoff_censor = cfg.max_wall_factor * max(total_handoff,
                                                       stage_wall)
            for _dep in stage.deps:
                while True:
                    if inj.virtual_time > handoff_censor:
                        return censored()
                    cost, from_server = fetch_cost() if endo \
                        else (stage.handoff, False)
                    if cost <= 0.0:
                        break
                    attempt_start = inj.virtual_time
                    try:
                        inj.advance_exposed(cost)
                        feed()
                        if from_server:
                            rep.server_bytes += img
                        break
                    except SimulatedFailure as f:
                        lost = f.at_virtual_time - attempt_start
                        rep.handoff_waste += lost
                        if from_server:
                            # The interrupted fetch still moved elapsed /
                            # total of the image through the shared pipe.
                            rep.server_bytes += img * min(lost / cost, 1.0)
                        feed()
            rep.handoff_time = inj.virtual_time
            superstep = 0
            payload = like
        else:
            superstep, payload = got
            rep.resumed = True
        rep.start_superstep = rep.committed_superstep = superstep

        # -------------------------------------------------------------- #
        # Superstep loop: compute, checkpoint at the live cadence, roll   #
        # back to the newest surviving replica on failure.                #
        # -------------------------------------------------------------- #
        v0 = inj.virtual_time
        stage_censor = cfg.max_wall_factor * stage_wall
        last_commit_v = inj.virtual_time
        while superstep < n_super:
            if inj.virtual_time - v0 > stage_censor:
                return censored()
            try:
                inj.advance_step()
                payload = task.step(payload, superstep)
                superstep += 1
                rep.executed_supersteps += 1
                if rep.first_step_real_s is None and real_t0 is not None:
                    rep.first_step_real_s = time.monotonic() - real_t0
                if kill is not None and \
                        rep.executed_supersteps >= kill.after_supersteps:
                    raise ExecutorKilled(stage.name, superstep)
                feed()
                if cfg.policy != "fixed":
                    # Fold hazard-weighted failure-free exposure; pure
                    # wasted work on the fixed-interval path, so skipped.
                    ctl.tick(inj.virtual_time,
                             exposure_peers=schedule.watch_hazard_sum())
                if superstep < n_super and \
                        inj.virtual_time - last_commit_v >= interval():
                    inj.advance_exposed(V)  # checkpoint stall, churn-exposed
                    ckpt.save(superstep, payload)
                    ckpt.wait()
                    rep.committed_superstep = superstep
                    rep.n_checkpoints += 1
                    rep.checkpoint_time += V
                    if endo and schedule.store.R == 0:
                        # Server-only mode uploads every image to the
                        # work-pool server; with peer replicas the image
                        # goes to holders and costs the server nothing.
                        rep.server_bytes += img
                    ctl.observe_checkpoint_overhead(V)
                    feed()
                    last_commit_v = inj.virtual_time
            except SimulatedFailure as f:
                # Everything since the last commit — uncommitted
                # supersteps, the partial one, any in-flight checkpoint —
                # is waste.
                rep.n_failures += 1
                rep.recompute_waste += f.at_virtual_time - last_commit_v
                feed()
                while True:  # restore, retried under churn (sim's loop)
                    if inj.virtual_time - v0 > stage_censor:
                        return censored()
                    attempt_start = inj.virtual_time
                    td, from_server = fetch_cost() if endo else (T_d, False)
                    try:
                        inj.advance_exposed(td)
                        feed()
                        rep.restore_time += td
                        if from_server:
                            rep.server_bytes += img
                            rep.n_server_restores += 1
                        break
                    except SimulatedFailure:
                        lost = inj.virtual_time - attempt_start
                        rep.restore_time += lost
                        if from_server and td > 0.0:
                            rep.server_bytes += img * min(lost / td, 1.0)
                        feed()
                ctl.observe_restore(td)
                rep.n_restores += 1
                restored = ckpt.restore_latest(like)
                if restored is not None:
                    superstep, payload = restored
                else:  # nothing durable yet: roll back to stage start
                    superstep, payload = 0, task.init(dep_payloads)
                rep.committed_superstep = superstep
                last_commit_v = inj.virtual_time
    except ScheduleExhausted:
        # A censoring-bound run (livelocked hand-off or restore-retry
        # loop) ran off the recorded horizon before hitting its wall
        # budget: beyond it the schedule carries no information, so the
        # stage is reported censored — never a crash.
        rep.schedule_exhausted = True
        return censored()

    # Persist the stage output (the image dependents fetch; also the resume
    # marker: committed step == n_super means complete).  No virtual cost —
    # the sim's final cycle omits V and bills the transfer on the edge.
    ckpt.save(n_super, payload)
    ckpt.wait()
    rep.committed_superstep = n_super
    rep.completed = True
    rep.final_interval = interval()
    rep.finish = inj.virtual_time
    return rep, payload
