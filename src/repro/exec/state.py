"""Executor state: stage layout on disk, reports, kill/resume protocol.

The resume protocol is entirely derivable from the checkpoint stores — no
separate progress database:

* each stage owns a primary directory plus ``n_replica_dirs`` neighbour
  directories (:func:`stage_paths`), all under one executor root, so an
  :class:`~repro.ckpt.async_ckpt.AsyncCheckpointer` per stage gives R-way
  HRW placement with corrupt-primary fallback;
* the checkpoint *step number is the superstep*: a committed image at step
  s means supersteps [0, s) are durable;
* a stage whose newest committed step >= its superstep count is complete —
  its payload is the stage output that dependents fetch.

:class:`ExecutorKilled` models a hard process death injected mid-superstep
(the crash-and-resume e2e): the in-flight superstep and everything after
the last committed checkpoint is lost, exactly like a real kill -9.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


class ExecutorKilled(Exception):
    """An injected hard kill — the simulated process dies mid-superstep."""

    def __init__(self, stage: str, superstep: int):
        super().__init__(f"stage {stage!r} killed at superstep {superstep}")
        self.stage = stage
        self.superstep = superstep


@dataclass(frozen=True)
class KillSpec:
    """Kill the process after ``after_supersteps`` supersteps have executed
    in ``stage`` during this incarnation (before anything else commits)."""

    stage: str
    after_supersteps: int

    def __post_init__(self) -> None:
        if self.after_supersteps <= 0:
            raise ValueError("after_supersteps must be positive")


@dataclass(frozen=True)
class ExecutorConfig:
    """Knobs of one executor deployment (shared by every stage).

    Virtual-time parameters (``V``, ``T_d``, priors, clamps) deliberately
    mirror :func:`repro.sim.workflow.simulate_workflow` /
    :class:`repro.core.adaptive.AdaptiveCheckpointController` defaults —
    digital-twin parity requires executor and sim to agree on them.
    ``seconds_per_superstep`` quantizes a stage's fault-free work into
    checkpointable steps; smaller steps track the twin's continuous cycle
    boundaries more closely at more per-step overhead.
    """

    root: str
    n_replica_dirs: int = 3
    replication_factor: Optional[int] = 2
    n_shards: int = 2
    seconds_per_superstep: float = 15.0
    V: float = 20.0
    T_d: float = 50.0
    policy: str = "adaptive"          # "adaptive" | "fixed"
    fixed_interval: float = 600.0
    prior_mu: float = 1.0 / (4 * 3600.0)
    mu_window: int = 32
    min_interval: float = 1.0
    max_interval: float = 24 * 3600.0
    max_wall_factor: float = 50.0

    def __post_init__(self) -> None:
        if self.policy not in ("adaptive", "fixed"):
            raise ValueError(f"unknown executor policy {self.policy!r}")
        if self.seconds_per_superstep <= 0:
            raise ValueError("seconds_per_superstep must be positive")
        if self.n_replica_dirs < 0 or self.n_shards <= 0:
            raise ValueError("need n_replica_dirs >= 0 and n_shards > 0")
        if self.replication_factor is not None and \
                self.replication_factor > self.n_replica_dirs:
            raise ValueError("replication_factor exceeds n_replica_dirs")


@dataclass(frozen=True)
class StagePaths:
    primary: str
    replicas: Tuple[str, ...]


def stage_paths(root: str, stage: str, n_replica_dirs: int) -> StagePaths:
    """Per-stage primary + neighbour replica directories.

    Each stage gets its own subtree of every directory so HRW placement is
    stage-local and one stage's gc can never evict another's images.
    """
    primary = os.path.join(root, "primary", stage)
    replicas = tuple(os.path.join(root, f"replica_{i}", stage)
                     for i in range(n_replica_dirs))
    return StagePaths(primary=primary, replicas=replicas)


@dataclass
class StageExecReport:
    """Measured (not simulated) accounting of one stage incarnation.

    Times are virtual seconds on the injector's clock — the same units the
    digital twin predicts — except ``first_step_real_s``, which is wall
    time on this machine (resume-latency telemetry).
    """

    name: str
    n_supersteps: int
    start_superstep: int = 0
    executed_supersteps: int = 0
    committed_superstep: int = 0
    ready: float = 0.0             # max dep finish (virtual, workflow clock)
    finish: float = 0.0            # ready + this incarnation's elapsed
    handoff_time: float = 0.0      # dep fetches incl. churn retries
    handoff_waste: float = 0.0     # fetch time lost to churn retries
    recompute_waste: float = 0.0   # rolled-back cycle time (paper's waste)
    checkpoint_time: float = 0.0
    restore_time: float = 0.0
    n_failures: int = 0
    n_checkpoints: int = 0
    n_restores: int = 0
    n_server_restores: int = 0     # endogenous restores that fell back to
                                   # the server (all replicas down)
    server_bytes: float = 0.0      # server I/O billed per attempt, the
                                   # engine's accounting (0 without store)
    final_interval: float = 0.0    # controller cadence at stage end
    completed: bool = False
    resumed: bool = False          # started from a prior incarnation's image
    schedule_exhausted: bool = False  # censored by running off the recorded
                                      # horizon, not by the wall budget
    first_step_real_s: Optional[float] = None

    @property
    def waste(self) -> float:
        """Total measured waste: recompute + hand-off retries (the quantity
        the sim's :func:`repro.sim.workflow.predicted_waste` predicts)."""
        return self.recompute_waste + self.handoff_waste

    @property
    def elapsed_virtual(self) -> float:
        return self.finish - self.ready


@dataclass
class ExecReport:
    """Whole-DAG execution report (one incarnation of the executor)."""

    stages: Dict[str, StageExecReport] = field(default_factory=dict)
    completed: bool = False
    makespan: float = 0.0          # virtual seconds, max stage finish
    real_seconds: float = 0.0      # wall time of this incarnation
    resume_latency_s: Optional[float] = None  # start -> first resumed step

    @property
    def total_waste(self) -> float:
        return sum(s.waste for s in self.stages.values())

    @property
    def server_bytes(self) -> float:
        """Aggregate work-pool server I/O across every stage (restores and
        hand-off fetches that fell back to the contended server path)."""
        return sum(s.server_bytes for s in self.stages.values())

    @property
    def executed_supersteps(self) -> int:
        return sum(s.executed_supersteps for s in self.stages.values())

    @property
    def steps_per_second(self) -> float:
        """Real (wall-clock) executor superstep throughput."""
        if self.real_seconds <= 0:
            return 0.0
        return self.executed_supersteps / self.real_seconds
