"""Resumable workflow executor — the sim's real-execution twin.

``repro.sim.workflow`` predicts a DAG's behaviour under churn;
``repro.exec`` runs the same DAG as real Python/JAX work units with
superstep checkpointing, P2P-style replication, and deterministic failure
injection replayed from the sim's exported schedules (DESIGN.md Sec 10).
"""
from repro.exec.executor import WorkflowExecutor
from repro.exec.state import (
    ExecReport,
    ExecutorConfig,
    ExecutorKilled,
    KillSpec,
    StageExecReport,
    StagePaths,
    stage_paths,
)
from repro.exec.superstep import run_stage
from repro.exec.tasks import MixTask, PowerIterTask, StageTask

__all__ = [
    "ExecReport",
    "ExecutorConfig",
    "ExecutorKilled",
    "KillSpec",
    "MixTask",
    "PowerIterTask",
    "StageExecReport",
    "StagePaths",
    "StageTask",
    "WorkflowExecutor",
    "run_stage",
    "stage_paths",
]
