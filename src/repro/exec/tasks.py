"""Work units the executor runs — the "real Python/JAX work" of a stage.

A :class:`StageTask` is the executor's unit of computation, quantized into
*supersteps* (the agent-workflow checkpoint-at-superstep idiom): the
executor calls :meth:`StageTask.step` once per superstep and may persist
the returned payload at any superstep boundary.  The contract that makes
crash-and-resume testable end-to-end:

* **Determinism** — ``step`` is a pure function of ``(payload, superstep)``
  and ``init`` of the dependency payloads, so a run killed at superstep s
  and resumed from the last committed checkpoint produces a final payload
  bit-identical to an uninterrupted run (tests/test_exec.py asserts this).
* **Serializability** — payloads are pytrees of arrays, exactly what
  :mod:`repro.ckpt.store` persists with integrity hashes.

Two reference tasks are provided: :class:`MixTask`, a cheap deterministic
NumPy recurrence for tests and benchmarks, and :class:`PowerIterTask`, a
jitted JAX power iteration whose matrix rides inside the checkpoint — the
"real JAX work unit" the examples execute.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class StageTask(Protocol):
    """One stage's work unit, advanced one superstep at a time."""

    def init(self, deps: Dict[str, Any]) -> Any:
        """The superstep-0 payload, folding in dependency outputs."""
        ...

    def step(self, payload: Any, superstep: int) -> Any:
        """The payload after executing ``superstep`` (pure, deterministic)."""
        ...


def _fold_scalar(payload: Any) -> float:
    """A deterministic scalar digest of a dependency payload, so DAG edges
    are load-bearing: corrupting or dropping a dependency changes every
    downstream payload."""
    leaves = []
    if isinstance(payload, dict):
        for key in sorted(payload):
            leaves.append(np.asarray(payload[key], dtype=np.float64))
    else:
        leaves.append(np.asarray(payload, dtype=np.float64))
    return float(sum(float(np.sum(np.cos(leaf))) for leaf in leaves))


@dataclass(frozen=True)
class MixTask:
    """Cheap deterministic NumPy recurrence (tests, benchmarks).

    ``x`` evolves by a contractive cosine map salted per superstep, and
    ``checksum`` accumulates a running digest — any lost or repeated
    superstep changes the final checksum, which is how the resume tests
    detect silently dropped work.
    """

    dim: int = 64
    salt: int = 0

    def init(self, deps: Dict[str, Any]) -> Dict[str, np.ndarray]:
        x = (np.arange(self.dim, dtype=np.float64) + 1.0) / self.dim \
            + float(self.salt)
        for name in sorted(deps):
            x = x + 1e-3 * _fold_scalar(deps[name])
        return {"x": x, "checksum": np.zeros((), dtype=np.float64)}

    def step(self, payload: Dict[str, Any], superstep: int) -> Dict[str, Any]:
        x = np.asarray(payload["x"], dtype=np.float64)
        x = np.cos(x * 1.0001) + 1e-6 * (superstep + self.salt)
        checksum = np.asarray(payload["checksum"], dtype=np.float64) \
            + np.float64(np.sum(x))
        return {"x": x, "checksum": checksum}


@functools.lru_cache(maxsize=None)
def _power_step_fn():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def step(mat, v):
        w = mat @ v
        nv = w / jnp.linalg.norm(w)
        return nv, jnp.vdot(v, w)

    return step


@dataclass(frozen=True)
class PowerIterTask:
    """A real JAX work unit: jitted power iteration on a PSD matrix.

    The matrix is derived deterministically from ``seed`` and carried in
    the payload (so it is checkpointed with the state, like optimizer
    state rides a training checkpoint); each superstep is one jitted
    matvec + normalize, converging ``eig`` to the dominant eigenvalue.
    """

    dim: int = 128
    seed: int = 0

    def init(self, deps: Dict[str, Any]) -> Dict[str, Any]:
        import jax
        import jax.numpy as jnp
        key = jax.random.PRNGKey(self.seed)
        a = jax.random.normal(key, (self.dim, self.dim), dtype=jnp.float32)
        mat = a @ a.T / self.dim + jnp.eye(self.dim, dtype=jnp.float32)
        v = jnp.ones((self.dim,), jnp.float32)
        for name in sorted(deps):
            v = v + jnp.float32(1e-3 * _fold_scalar(deps[name]))
        return {"mat": np.asarray(mat),
                "v": np.asarray(v / jnp.linalg.norm(v)),
                "eig": np.zeros((), dtype=np.float32)}

    def step(self, payload: Dict[str, Any], superstep: int) -> Dict[str, Any]:
        v, eig = _power_step_fn()(payload["mat"], payload["v"])
        return {"mat": payload["mat"], "v": np.asarray(v),
                "eig": np.asarray(eig, dtype=np.float32)}
