"""P2P storage overlay: holder membership under churn and replica placement.

The paper's architecture off-loads checkpoint storage from the work-pool
server onto the peers themselves (Sec 1-2): each job's checkpoint image is
replicated to R *holder* peers picked from the overlay.  Holders churn like
every other volunteer, so the overlay continuously re-replicates: when a
holder departs, a replacement is recruited and the image re-copied from a
surviving replica (or the server's master copy when none survives).

This module models that membership process and the placement rule:

* :func:`availability` — stationary probability that one holder slot is
  serving.  A slot alternates ALIVE (Exp lifetime, hazard mu) and REPAIRING
  (mean ``t_repair`` to recruit + re-copy); by alternating-renewal theory
  the up-fraction is E[up] / (E[up] + E[down]) = 1 / (1 + mu * t_repair),
  independent of the repair-time distribution.
* :class:`ReplicaSetProcess` — the exact event-driven R-slot process, used
  as the parity oracle for the batched engine's closed-form replica-
  survival law (each slot i.i.d. Bernoulli(availability) at any instant —
  exact in steady state because exponential phases are memoryless and the
  process is started stationary).
* :func:`rendezvous_placement` — highest-random-weight (HRW) placement of
  an item on R of N nodes.  Deterministic given (key, membership), so every
  peer computes the same holder set with no coordination — the same
  "no additional message" property the paper's estimator piggybacking has —
  and membership changes only remap the items whose holders departed.
* :func:`stationary_loss_rate` — the exact steady-state rate at which the
  replica SET transitions to all-dead, cross-checked in the tests against
  the small-rate approximation ``repro.core.replication.
  effective_failure_rate`` and against :class:`ReplicaSetProcess`.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Callable, List, Optional, Sequence, Tuple

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - annotation only (avoids an import
    # cycle: repro.sim.engine imports repro.p2p.store -> this module at
    # package-init time, before repro.sim.scenarios finishes loading)
    from repro.sim.scenarios import ShockClock, ShockSpec

MtbfFn = Callable[[float], float]  # wall time (s) -> current MTBF (s)


def availability(mu: float, t_repair: float) -> float:
    """Stationary up-probability of one holder slot: 1 / (1 + mu*t_repair)."""
    if mu < 0 or t_repair < 0:
        raise ValueError("mu and t_repair must be non-negative")
    return 1.0 / (1.0 + mu * t_repair)


def shock_availability(mu: float, t_repair: float, shock_rate: float = 0.0,
                       kill_frac: float = 0.0) -> float:
    """Stationary holder availability under correlated shocks.

    Shock epochs (Poisson, ``shock_rate``) kill an up holder with
    probability ``kill_frac``; thinning makes the holder's shock-death
    process Poisson with rate ``shock_rate * kill_frac``, and the
    superposition with the background Exp(mu) hazard is still memoryless —
    so alternating-renewal applies *exactly* with the effective hazard:

        A = 1 / (1 + (mu + shock_rate*kill_frac) * t_repair)

    The MARGINAL is unchanged from an i.i.d. fleet with that rate; what
    shocks change is the joint law — see :func:`shock_survivor_pmf`.
    """
    if shock_rate < 0 or not 0.0 <= kill_frac <= 1.0:
        raise ValueError("shock_rate must be >= 0 and kill_frac in [0, 1]")
    return availability(mu + shock_rate * kill_frac, t_repair)


def shock_survivor_pmf(R: int, mu: float, t_repair: float, shock_rate: float,
                       kill_frac: float, job_fail_rate: float,
                       job_kill_prob: float) -> np.ndarray:
    """Exact survivor-count law seen by a restore attempt under shocks.

    Without shocks every restore finds m ~ Binomial(R, A) survivors (each
    holder's stationary Bernoulli is independent of the job's failure
    instant).  With shocks the restore *instant is not exchangeable*: a
    job failure was caused by a shock with probability

        q = shock_rate * job_kill_prob
            / (job_fail_rate + shock_rate * job_kill_prob)

    (the exponential race between the background job-failure process at
    ``job_fail_rate`` and the thinned shock-kill process), and conditional
    on a shock-caused failure each in-scope holder was additionally killed
    by THAT shock with probability ``kill_frac`` — so survivors drop to
    Binomial(R, A*(1-kill_frac)).  The attempt-time law is the mixture

        P(m) = q * Binom(R, A*(1-f))(m) + (1-q) * Binom(R, A)(m)

    with A = :func:`shock_availability`.  This is the closed form the
    batched engine samples branchlessly; independence (q = 0) strictly
    stochastically dominates it, which is exactly how an i.i.d. law
    undercounts replica loss under correlated churn.
    """
    if R < 0:
        raise ValueError("replication factor must be >= 0")
    if job_fail_rate < 0 or not 0.0 <= job_kill_prob <= 1.0:
        raise ValueError("job_fail_rate >= 0 and job_kill_prob in [0, 1]")
    A = shock_availability(mu, t_repair, shock_rate, kill_frac)
    s_kill = shock_rate * job_kill_prob
    denom = job_fail_rate + s_kill
    q = s_kill / denom if denom > 0 else 0.0
    A_post = A * (1.0 - kill_frac)

    def binom(p: float) -> np.ndarray:
        return np.array([math.comb(R, m) * p ** m * (1.0 - p) ** (R - m)
                         for m in range(R + 1)])

    return q * binom(A_post) + (1.0 - q) * binom(A)


def stationary_loss_rate(mu: float, R: int, t_repair: float) -> float:
    """Exact steady-state rate of replica-set loss (all R holders dead).

    The set enters the all-dead state when exactly one holder is alive and
    it dies: rate = P(exactly 1 alive) * mu = R * A * (1-A)^(R-1) * mu with
    A = availability(mu, t_repair).  For mu*t_repair << 1 this reduces to
    R * mu * (mu*t_repair)^(R-1), the small-rate cascade approximation of
    :func:`repro.core.replication.effective_failure_rate`.
    """
    if R < 1:
        raise ValueError("replication factor must be >= 1")
    A = availability(mu, t_repair)
    return R * A * (1.0 - A) ** (R - 1) * mu


@dataclass(frozen=True)
class HolderTrack:
    """One holder slot's pinned up/down realization (DESIGN.md Sec 10).

    ``toggles`` are the ascending wall times at which the slot flips state,
    starting from ``init_up``: an even number of toggles before t leaves the
    slot in its initial state at t.  A tuple of tracks IS the replica-set
    realization — serialized into :class:`repro.runtime.failures.
    StageSchedule` so the sim's prediction and the executor's measurement
    answer "who is alive at t?" from the same data.
    """

    init_up: bool
    toggles: Tuple[float, ...] = ()

    def __post_init__(self) -> None:
        ts = self.toggles
        if any(b < a for a, b in zip(ts, ts[1:])):
            raise ValueError("holder toggles must be time-ordered")


class ReplicaSetProcess:
    """Event-driven alternating-renewal process of R holder slots.

    Each slot alternates ALIVE (lifetime ~ Exp with the birth-time hazard
    of ``mtbf_fn``) and REPAIRING (replacement recruitment + re-copy,
    duration ~ Exp(mean ``t_repair``)).  Repair is always possible: a
    replacement copies from a surviving replica, or from the work-pool
    server's master copy when none survives (the paper's server fallback).

    The process is initialized *stationary* at ``t0`` — each slot up with
    probability :func:`availability` and exponential phases are memoryless —
    so the marginal of :meth:`n_alive` at any later time is exactly
    Binomial(R, availability(mu, t_repair)) under constant churn.  This is
    the per-replica parity oracle for the batched engine's closed-form law.
    """

    def __init__(self, R: int, mtbf_fn: MtbfFn, t_repair: float,
                 rng: np.random.Generator, t0: float = 0.0,
                 slot_mults: Optional[Sequence[float]] = None,
                 shock: Optional["ShockSpec"] = None,
                 shock_clock: Optional["ShockClock"] = None,
                 shock_rng: Optional[np.random.Generator] = None,
                 scope_mask: Optional[Sequence[bool]] = None):
        """``slot_mults`` gives holder slot ``i`` a hazard multiplier
        (heterogeneous fleets, DESIGN.md Sec 7): its lifetimes are
        Exp(mtbf/mult) and its stationary availability
        1/(1 + mult*mu*t_repair).  ``None`` keeps the homogeneous process,
        with an unchanged RNG call sequence.

        ``shock`` adds correlated mass-kill epochs (DESIGN.md Sec 8): at
        each epoch of ``shock_clock`` every UP in-scope holder dies
        independently with probability ``kill_frac`` and enters repair.
        Pass the SAME clock as the job's :class:`ChurnNetwork` so holder
        losses coincide with the job failures that trigger restores —
        the correlation the engine's mixture law models.  ``shock_rng``
        (kill Bernoullis) and the clock are derived from ``rng`` when
        omitted; ``scope_mask`` restricts kills to a holder subset.  With
        ``shock=None`` the RNG call sequence is unchanged bit-for-bit.
        """
        if R < 0:
            raise ValueError("replication factor must be >= 0")
        if t_repair <= 0:
            raise ValueError("t_repair must be positive")
        if slot_mults is not None:
            slot_mults = tuple(float(m) for m in slot_mults)
            if len(slot_mults) != R:
                raise ValueError(
                    f"need one hazard multiplier per holder: "
                    f"{len(slot_mults)} != {R}")
            if slot_mults and min(slot_mults) <= 0:
                raise ValueError("holder hazard multipliers must be positive")
        self.R = R
        self.mtbf_fn = mtbf_fn
        self.t_repair = float(t_repair)
        self.rng = rng
        self.slot_mults = slot_mults
        self.t0 = float(t0)
        self.t = float(t0)
        self.n_losses = 0  # transitions into the all-dead state
        self.shock = shock
        self._shock_i = 0
        if shock is not None:
            if scope_mask is None:
                scope_mask = (True,) * R
            scope_mask = tuple(bool(b) for b in scope_mask)
            if len(scope_mask) != R:
                raise ValueError("need one scope flag per holder slot")
            self._scope = scope_mask
            # Spawned (not drawn) from the main rng, so attaching a shock
            # leaves the holder lifetime/repair draws bit-identical.
            kids = rng.spawn(2)
            if shock_clock is None:
                from repro.sim.scenarios import ShockClock  # runtime-safe
                shock_clock = ShockClock(shock.rate, kids[0])
            self._clock = shock_clock
            self._shock_rng = shock_rng if shock_rng is not None else kids[1]
            # Epochs before t0 predate the (stationary) start of this
            # process: skip them so a late-created replica set does not
            # replay history.
            while self._clock.epoch(self._shock_i) <= t0:
                self._shock_i += 1
        mtbf0 = mtbf_fn(t0)
        self._replay = None  # live process; set by from_lifetimes
        self._up = np.zeros(R, dtype=bool)
        self._next = np.full(R, np.inf)
        for i in range(R):
            mult = slot_mults[i] if slot_mults is not None else 1.0
            # Stationary init: the shock adds a thinned-Poisson kill rate
            # (rate * kill_frac for in-scope slots) to the holder's hazard;
            # the superposed up-phase is still exponential, so the
            # alternating-renewal marginal is exact (shock_availability).
            mu_i = mult / mtbf0
            if shock is not None and self._scope[i]:
                A = shock_availability(mu_i, t_repair, shock.rate,
                                       shock.kill_frac)
            else:
                A = availability(mu_i, t_repair)
            self._up[i] = rng.random() < A
            hold = mtbf0 / mult if self._up[i] else t_repair
            self._next[i] = t0 + rng.exponential(hold)
        # Transition log: every state flip of every slot, so the advanced
        # prefix of the process can be serialized (lifetimes_until) and
        # replayed bit-exactly by a from_lifetimes view.  R <= 8, cheap.
        self._init_up = tuple(bool(u) for u in self._up)
        self._toggles: List[List[float]] = [[] for _ in range(R)]

    # ------------------------------------------------------------------ #
    # Pinned-realization (replay) view.                                   #
    # ------------------------------------------------------------------ #
    @classmethod
    def from_lifetimes(cls, tracks: Sequence[HolderTrack], t0: float = 0.0,
                       horizon: float = math.inf) -> "ReplicaSetProcess":
        """A replayable view over pinned holder realizations — no RNG.

        ``tracks`` come from :meth:`lifetimes_until` (directly or via a
        serialized :class:`repro.runtime.failures.StageSchedule`); the view
        answers :meth:`n_alive` / :meth:`alive_slots` by walking the pinned
        toggle lists, so the heap oracle, the engine's closed-form law, and
        the executor all consult the same realization.  Advancing past
        ``horizon`` raises :class:`repro.runtime.failures.ScheduleExhausted`
        — beyond it the tracks carry no information (absence of toggles
        there means "not generated", not "still up").
        """
        self = cls.__new__(cls)
        self.R = len(tracks)
        self.mtbf_fn = None
        self.t_repair = 0.0
        self.rng = None
        self.slot_mults = None
        self.shock = None
        self.t0 = float(t0)
        self.t = float(t0)
        self.n_losses = 0
        self._replay = tuple(tuple(tr.toggles) for tr in tracks)
        self._replay_horizon = float(horizon)
        self._cursor = [0] * self.R
        self._up = np.array([tr.init_up for tr in tracks], dtype=bool)
        self._init_up = tuple(tr.init_up for tr in tracks)
        self._toggles = [list(tr.toggles) for tr in tracks]
        return self

    def lifetimes_until(self, horizon: float) -> Tuple[HolderTrack, ...]:
        """Advance to ``horizon`` and serialize the realization so far."""
        self.advance(horizon)
        return tuple(HolderTrack(init_up=self._init_up[i],
                                 toggles=tuple(self._toggles[i]))
                     for i in range(self.R))

    def _advance_replay(self, t: float) -> None:
        if t > self._replay_horizon:
            from repro.runtime.failures import ScheduleExhausted
            raise ScheduleExhausted(
                f"holder replay advanced to t={t:.1f}s past the recorded "
                f"horizon {self._replay_horizon:.1f}s")
        due: List[Tuple[float, int]] = []
        for i in range(self.R):
            toggles = self._replay[i]
            c = self._cursor[i]
            while c < len(toggles) and toggles[c] <= t:
                due.append((toggles[c], i))
                c += 1
            self._cursor[i] = c
        # Time-ordered across slots so the all-dead transition count is
        # exact even when toggles of different slots interleave.
        for _, i in sorted(due):
            was_any = bool(self._up.any())
            self._up[i] = not self._up[i]
            if was_any and not self._up.any():
                self.n_losses += 1
        self.t = max(self.t, float(t))

    def _slot_mtbf(self, i: int, t: float) -> float:
        m = self.mtbf_fn(t)
        return m / self.slot_mults[i] if self.slot_mults is not None else m

    def _next_shock_time(self) -> float:
        return (self._clock.epoch(self._shock_i)
                if self.shock is not None else math.inf)

    def advance(self, t: float) -> None:
        """Process holder deaths/repairs/shock epochs up to ``t``, in order."""
        if self._replay is not None:
            self._advance_replay(t)
            return
        while self.R:
            i = int(np.argmin(self._next))
            te = float(self._next[i])
            ts = self._next_shock_time()
            if min(te, ts) > t:
                break
            if ts <= te:
                # Mass-kill epoch: every UP in-scope holder dies w.p.
                # kill_frac, simultaneously; its pending natural death is
                # superseded by the repair completion.
                self._shock_i += 1
                f = self.shock.kill_frac
                was_up = bool(self._up.any())
                for j in range(self.R):
                    if self._up[j] and self._scope[j] \
                            and self._shock_rng.random() < f:
                        self._up[j] = False
                        self._toggles[j].append(ts)
                        self._next[j] = ts + self.rng.exponential(self.t_repair)
                if was_up and not self._up.any():
                    self.n_losses += 1
                continue
            if self._up[i]:
                self._up[i] = False
                self._toggles[i].append(te)
                self._next[i] = te + self.rng.exponential(self.t_repair)
                if not self._up.any():
                    self.n_losses += 1
            else:
                self._up[i] = True
                self._toggles[i].append(te)
                self._next[i] = te + self.rng.exponential(self._slot_mtbf(i, te))
        self.t = max(self.t, float(t))

    def n_alive(self, t: float) -> int:
        """Surviving replica count at wall time ``t`` (advances the process)."""
        self.advance(t)
        return int(self._up.sum())

    def alive_slots(self, t: float) -> List[int]:
        """Indices of the holders alive at ``t`` (advances the process) —
        class-aware restores stripe over exactly these slots' uplinks."""
        self.advance(t)
        return [i for i in range(self.R) if self._up[i]]

    def loss_rate(self) -> float:
        """Observed all-dead transition rate over the advanced horizon."""
        elapsed = self.t - self.t0
        return self.n_losses / elapsed if elapsed > 0 else 0.0


def rendezvous_placement(key: str, nodes: Sequence[str], R: int,
                         weights: Optional[Sequence[float]] = None) -> List[str]:
    """Pick R of ``nodes`` to hold ``key`` by highest-random-weight hashing.

    Every participant evaluates the same deterministic score
    sha1(key | node), so the holder set needs no coordinator, and removing
    a node only remaps the keys it held (minimal disruption — the property
    that keeps re-replication traffic proportional to churn, not to the
    population).

    ``weights`` enables *weighted* rendezvous hashing (heterogeneous
    fleets): node ``i`` wins proportionally to ``weights[i]`` via the
    standard -w/ln(u) transform of its unit-interval hash — e.g. weight by
    class availability so stable, fat-uplink peers hold more replicas.
    ``None`` keeps the classic unweighted ordering, unchanged.
    """
    if R < 0:
        raise ValueError("replication factor must be >= 0")
    if weights is None:
        scored = sorted(
            nodes,
            key=lambda nd: hashlib.sha1(f"{key}|{nd}".encode()).hexdigest(),
            reverse=True,
        )
        return list(scored[:min(R, len(scored))])
    if len(weights) != len(nodes):
        raise ValueError("need one weight per node")
    if any(w <= 0 for w in weights):
        raise ValueError("placement weights must be positive")

    def score(nd: str, w: float) -> float:
        h = hashlib.sha1(f"{key}|{nd}".encode()).digest()
        # 53 bits of the digest -> u in (0, 1); -w/ln(u) is the classic
        # weighted-rendezvous score (monotone in w, continuous in u).
        u = (int.from_bytes(h[:8], "big") >> 11 | 1) / float(1 << 53)
        return -w / math.log(u)

    scored = sorted(zip(nodes, weights), key=lambda p: score(*p), reverse=True)
    return [nd for nd, _ in scored[:min(R, len(nodes))]]
