"""P2P storage overlay: holder membership under churn and replica placement.

The paper's architecture off-loads checkpoint storage from the work-pool
server onto the peers themselves (Sec 1-2): each job's checkpoint image is
replicated to R *holder* peers picked from the overlay.  Holders churn like
every other volunteer, so the overlay continuously re-replicates: when a
holder departs, a replacement is recruited and the image re-copied from a
surviving replica (or the server's master copy when none survives).

This module models that membership process and the placement rule:

* :func:`availability` — stationary probability that one holder slot is
  serving.  A slot alternates ALIVE (Exp lifetime, hazard mu) and REPAIRING
  (mean ``t_repair`` to recruit + re-copy); by alternating-renewal theory
  the up-fraction is E[up] / (E[up] + E[down]) = 1 / (1 + mu * t_repair),
  independent of the repair-time distribution.
* :class:`ReplicaSetProcess` — the exact event-driven R-slot process, used
  as the parity oracle for the batched engine's closed-form replica-
  survival law (each slot i.i.d. Bernoulli(availability) at any instant —
  exact in steady state because exponential phases are memoryless and the
  process is started stationary).
* :func:`rendezvous_placement` — highest-random-weight (HRW) placement of
  an item on R of N nodes.  Deterministic given (key, membership), so every
  peer computes the same holder set with no coordination — the same
  "no additional message" property the paper's estimator piggybacking has —
  and membership changes only remap the items whose holders departed.
* :func:`stationary_loss_rate` — the exact steady-state rate at which the
  replica SET transitions to all-dead, cross-checked in the tests against
  the small-rate approximation ``repro.core.replication.
  effective_failure_rate`` and against :class:`ReplicaSetProcess`.
"""
from __future__ import annotations

import hashlib
import math
from typing import Callable, List, Optional, Sequence

import numpy as np

MtbfFn = Callable[[float], float]  # wall time (s) -> per-peer MTBF (s)


def availability(mu: float, t_repair: float) -> float:
    """Stationary up-probability of one holder slot: 1 / (1 + mu*t_repair)."""
    if mu < 0 or t_repair < 0:
        raise ValueError("mu and t_repair must be non-negative")
    return 1.0 / (1.0 + mu * t_repair)


def stationary_loss_rate(mu: float, R: int, t_repair: float) -> float:
    """Exact steady-state rate of replica-set loss (all R holders dead).

    The set enters the all-dead state when exactly one holder is alive and
    it dies: rate = P(exactly 1 alive) * mu = R * A * (1-A)^(R-1) * mu with
    A = availability(mu, t_repair).  For mu*t_repair << 1 this reduces to
    R * mu * (mu*t_repair)^(R-1), the small-rate cascade approximation of
    :func:`repro.core.replication.effective_failure_rate`.
    """
    if R < 1:
        raise ValueError("replication factor must be >= 1")
    A = availability(mu, t_repair)
    return R * A * (1.0 - A) ** (R - 1) * mu


class ReplicaSetProcess:
    """Event-driven alternating-renewal process of R holder slots.

    Each slot alternates ALIVE (lifetime ~ Exp with the birth-time hazard
    of ``mtbf_fn``) and REPAIRING (replacement recruitment + re-copy,
    duration ~ Exp(mean ``t_repair``)).  Repair is always possible: a
    replacement copies from a surviving replica, or from the work-pool
    server's master copy when none survives (the paper's server fallback).

    The process is initialized *stationary* at ``t0`` — each slot up with
    probability :func:`availability` and exponential phases are memoryless —
    so the marginal of :meth:`n_alive` at any later time is exactly
    Binomial(R, availability(mu, t_repair)) under constant churn.  This is
    the per-replica parity oracle for the batched engine's closed-form law.
    """

    def __init__(self, R: int, mtbf_fn: MtbfFn, t_repair: float,
                 rng: np.random.Generator, t0: float = 0.0,
                 slot_mults: Optional[Sequence[float]] = None):
        """``slot_mults`` gives holder slot ``i`` a hazard multiplier
        (heterogeneous fleets, DESIGN.md Sec 7): its lifetimes are
        Exp(mtbf/mult) and its stationary availability
        1/(1 + mult*mu*t_repair).  ``None`` keeps the homogeneous process,
        with an unchanged RNG call sequence."""
        if R < 0:
            raise ValueError("replication factor must be >= 0")
        if t_repair <= 0:
            raise ValueError("t_repair must be positive")
        if slot_mults is not None:
            slot_mults = tuple(float(m) for m in slot_mults)
            if len(slot_mults) != R:
                raise ValueError(
                    f"need one hazard multiplier per holder: "
                    f"{len(slot_mults)} != {R}")
            if slot_mults and min(slot_mults) <= 0:
                raise ValueError("holder hazard multipliers must be positive")
        self.R = R
        self.mtbf_fn = mtbf_fn
        self.t_repair = float(t_repair)
        self.rng = rng
        self.slot_mults = slot_mults
        self.t0 = float(t0)
        self.t = float(t0)
        self.n_losses = 0  # transitions into the all-dead state
        mtbf0 = mtbf_fn(t0)
        self._up = np.zeros(R, dtype=bool)
        self._next = np.full(R, np.inf)
        for i in range(R):
            mult = slot_mults[i] if slot_mults is not None else 1.0
            A = availability(mult / mtbf0, t_repair)
            self._up[i] = rng.random() < A
            hold = mtbf0 / mult if self._up[i] else t_repair
            self._next[i] = t0 + rng.exponential(hold)

    def _slot_mtbf(self, i: int, t: float) -> float:
        m = self.mtbf_fn(t)
        return m / self.slot_mults[i] if self.slot_mults is not None else m

    def advance(self, t: float) -> None:
        """Process holder deaths/repairs up to wall time ``t``, in order."""
        while self.R:
            i = int(np.argmin(self._next))
            te = float(self._next[i])
            if te > t:
                break
            if self._up[i]:
                self._up[i] = False
                self._next[i] = te + self.rng.exponential(self.t_repair)
                if not self._up.any():
                    self.n_losses += 1
            else:
                self._up[i] = True
                self._next[i] = te + self.rng.exponential(self._slot_mtbf(i, te))
        self.t = max(self.t, float(t))

    def n_alive(self, t: float) -> int:
        """Surviving replica count at wall time ``t`` (advances the process)."""
        self.advance(t)
        return int(self._up.sum())

    def alive_slots(self, t: float) -> List[int]:
        """Indices of the holders alive at ``t`` (advances the process) —
        class-aware restores stripe over exactly these slots' uplinks."""
        self.advance(t)
        return [i for i in range(self.R) if self._up[i]]

    def loss_rate(self) -> float:
        """Observed all-dead transition rate over the advanced horizon."""
        elapsed = self.t - self.t0
        return self.n_losses / elapsed if elapsed > 0 else 0.0


def rendezvous_placement(key: str, nodes: Sequence[str], R: int,
                         weights: Optional[Sequence[float]] = None) -> List[str]:
    """Pick R of ``nodes`` to hold ``key`` by highest-random-weight hashing.

    Every participant evaluates the same deterministic score
    sha1(key | node), so the holder set needs no coordinator, and removing
    a node only remaps the keys it held (minimal disruption — the property
    that keeps re-replication traffic proportional to churn, not to the
    population).

    ``weights`` enables *weighted* rendezvous hashing (heterogeneous
    fleets): node ``i`` wins proportionally to ``weights[i]`` via the
    standard -w/ln(u) transform of its unit-interval hash — e.g. weight by
    class availability so stable, fat-uplink peers hold more replicas.
    ``None`` keeps the classic unweighted ordering, unchanged.
    """
    if R < 0:
        raise ValueError("replication factor must be >= 0")
    if weights is None:
        scored = sorted(
            nodes,
            key=lambda nd: hashlib.sha1(f"{key}|{nd}".encode()).hexdigest(),
            reverse=True,
        )
        return list(scored[:min(R, len(scored))])
    if len(weights) != len(nodes):
        raise ValueError("need one weight per node")
    if any(w <= 0 for w in weights):
        raise ValueError("placement weights must be positive")

    def score(nd: str, w: float) -> float:
        h = hashlib.sha1(f"{key}|{nd}".encode()).digest()
        # 53 bits of the digest -> u in (0, 1); -w/ln(u) is the classic
        # weighted-rendezvous score (monotone in w, continuous in u).
        u = (int.from_bytes(h[:8], "big") >> 11 | 1) / float(1 << 53)
        return -w / math.log(u)

    scored = sorted(zip(nodes, weights), key=lambda p: score(*p), reverse=True)
    return [nd for nd, _ in scored[:min(R, len(nodes))]]
