"""Peer-to-peer checkpoint-storage overlay (DESIGN.md Sec 6).

Models *where* checkpoint replicas live and what they cost to fetch, so
the restore time T_d the rest of the system consumes is endogenous:

* :mod:`repro.p2p.overlay` — holder membership under churn (alternating-
  renewal replica slots, stationary availability, HRW placement).
* :mod:`repro.p2p.transfer` — peer-uplink striping vs the contended
  work-pool server pipe.
* :mod:`repro.p2p.store` — :class:`StoreSpec` for the batched engine and
  the per-event :class:`P2PCheckpointStore` parity oracle.

This package is deliberately independent of :mod:`repro.sim` (the sim
layer imports it, not the reverse) so the same placement/transfer laws
also drive the real checkpointer (:mod:`repro.ckpt.async_ckpt`).
"""
from repro.p2p.overlay import (
    HolderTrack,
    ReplicaSetProcess,
    availability,
    rendezvous_placement,
    shock_availability,
    shock_survivor_pmf,
    stationary_loss_rate,
)
from repro.p2p.store import R_MAX, P2PCheckpointStore, StoreSpec
from repro.p2p.transfer import TransferModel

__all__ = [
    "HolderTrack",
    "P2PCheckpointStore",
    "R_MAX",
    "ReplicaSetProcess",
    "StoreSpec",
    "TransferModel",
    "availability",
    "rendezvous_placement",
    "shock_availability",
    "shock_survivor_pmf",
    "stationary_loss_rate",
]
