"""Bandwidth/transfer model for checkpoint images (peer uplinks vs server).

Anderson & Fedak quantify the volunteer fleet's aggregate storage and
network capacity: individually slow peer uplinks, in aggregate dwarfing
the project server's shared pipe.  This module turns those capacities into
restore/fetch times:

* fetching from m surviving peer replicas stripes the image across their
  uplinks, capped by the restoring peer's downlink:
  ``t = img / min(m * peer_uplink, peer_downlink)``;
* falling back to the work-pool server pays for the shared pipe: the
  server's capacity is divided among ``server_load`` concurrent flows
  (checkpoint uploads, input downloads, other jobs' restores), so one
  restore gets ``server_capacity / (1 + server_load)``.

These two laws are what make the paper's restore time T_d *endogenous*:
the engine derives every restore's duration from the surviving replica
count and this model instead of treating T_d as an exogenous constant.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class TransferModel:
    """Link capacities and image size, all in bytes / bytes-per-second."""

    img_bytes: float = 200e6        # checkpoint image size
    peer_uplink: float = 5e6        # one holder's serving bandwidth
    peer_downlink: float = 50e6     # restoring peer's receive cap
    server_capacity: float = 100e6  # work-pool server's shared pipe
    server_load: float = 20.0       # concurrent flows sharing that pipe

    def __post_init__(self) -> None:
        if min(self.img_bytes, self.peer_uplink, self.peer_downlink,
               self.server_capacity) <= 0:
            raise ValueError("sizes and bandwidths must be positive")
        if self.server_load < 0:
            raise ValueError("server_load must be non-negative")

    # ------------------------------------------------------------------ #
    @property
    def server_share(self) -> float:
        """Bandwidth one flow gets from the contended server pipe."""
        return self.server_capacity / (1.0 + self.server_load)

    def server_seconds(self) -> float:
        """Restore duration from the server (the m=0 fallback)."""
        return self.img_bytes / self.server_share

    def peer_seconds(self, m: int) -> float:
        """Restore duration striped across m >= 1 surviving replicas."""
        if m < 1:
            raise ValueError("need at least one surviving replica")
        return self.img_bytes / min(m * self.peer_uplink, self.peer_downlink)

    def restore_seconds(self, m: int) -> float:
        """Endogenous T_d for a restore finding m surviving replicas."""
        return self.peer_seconds(m) if m >= 1 else self.server_seconds()

    def restore_seconds_from(self, uplink_mults) -> float:
        """Endogenous T_d striped over a *heterogeneous* surviving set.

        ``uplink_mults`` are the surviving holders' class uplink multipliers
        (DESIGN.md Sec 7): holder i serves at ``uplink_mults[i] *
        peer_uplink``, the stripe is capped by the restoring peer's
        downlink, and an empty set falls back to the server.  With all
        multipliers 1.0 this is exactly :meth:`restore_seconds` of the
        count.

        The zero-survivor branch must stay total (DESIGN.md Sec 8): a
        correlated shock routinely empties the whole surviving set, and the
        restore then *must* come back as the finite server-fallback time —
        never a divide-by-zero or inf that would wedge the retry loop.  The
        ``not total > 0`` form also routes a NaN aggregate to the fallback.
        """
        total = math.fsum(uplink_mults) * self.peer_uplink
        if not total > 0.0:
            return self.server_seconds()
        return self.img_bytes / min(total, self.peer_downlink)

    def expected_restore_seconds(self, R: int, avail: float) -> float:
        """E[T_d] under m ~ Binomial(R, avail) — the oracle policy's view."""
        if not 0.0 <= avail <= 1.0:
            raise ValueError("avail must be a probability")
        return sum(
            math.comb(R, m) * avail ** m * (1.0 - avail) ** (R - m)
            * self.restore_seconds(m)
            for m in range(R + 1)
        )


def striped_restore_seconds(m, td_up1, td_cap, td_server, xp):
    """Vectorized :meth:`TransferModel.restore_seconds`: peer-uplink
    striping ``max(td_up1/m, td_cap)`` for m >= 1, server fallback for
    m = 0.  The ONE place the transfer law lives for array consumers —
    the batched engine (packed per-cell scalars) and the workflow's edge
    fetches both call this, so the laws cannot drift apart.  ``xp`` is
    ``numpy`` or ``jax.numpy``.
    """
    td_m = xp.maximum(td_up1 / xp.maximum(m, 1.0), td_cap)
    return xp.where(m >= 1.0, td_m, td_server)
