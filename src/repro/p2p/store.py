"""The P2P checkpoint store: spec for the batched engine + per-event oracle.

:class:`StoreSpec` is the complete, hashable description a simulation cell
carries: replication factor, re-replication (repair) time, and the
:class:`~repro.p2p.transfer.TransferModel`.  The batched engine packs its
derived scalars (``td_up1``, ``td_cap``, ``td_server``) and samples the
surviving-replica count in closed form — m ~ Binomial(R, availability) —
so the ``lax.scan`` step stays batched.

:class:`P2PCheckpointStore` is the per-event counterpart driving the heap
reference simulator (:func:`repro.sim.job.simulate_job`): an exact
:class:`~repro.p2p.overlay.ReplicaSetProcess` evolves individual holder
deaths and repairs, and every restore reads the *actual* surviving count.
The engine's closed form must reproduce its statistics
(tests/test_p2p.py), the same parity discipline the engine already holds
against the heap for the churn process itself.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable, Optional

import numpy as np

from repro.p2p.overlay import ReplicaSetProcess, availability
from repro.p2p.transfer import TransferModel

if TYPE_CHECKING:  # pragma: no cover - annotation only (avoids an import
    # cycle: repro.sim.engine imports this module at package-init time)
    from repro.sim.scenarios import PeerClassMix, ShockClock, ShockSpec

# The batched engine unrolls the Binomial(R, A) inverse-CDF over a fixed
# number of terms; R beyond this adds no meaningful availability anyway
# (loss probability is already (mu*t_repair)^R).
R_MAX = 8


@dataclass(frozen=True)
class StoreSpec:
    """Replica placement + transfer description carried by a simulation cell.

    ``R = 0`` is the server-only baseline: no peer replicas, every restore
    (and every checkpoint upload) hits the work-pool server.
    """

    R: int = 3
    t_repair: float = 600.0            # recruit replacement + re-copy image
    transfer: TransferModel = field(default_factory=TransferModel)

    def __post_init__(self) -> None:
        if not 0 <= self.R <= R_MAX:
            raise ValueError(f"R must be in [0, {R_MAX}]")
        if self.t_repair <= 0:
            raise ValueError("t_repair must be positive")

    # Packed scalars for the vectorized engine: restore from m sources is
    # max(td_up1 / m, td_cap), i.e. img / min(m*uplink, downlink).
    @property
    def td_up1(self) -> float:
        return self.transfer.img_bytes / self.transfer.peer_uplink

    @property
    def td_cap(self) -> float:
        return self.transfer.img_bytes / self.transfer.peer_downlink

    @property
    def td_server(self) -> float:
        return self.transfer.server_seconds()

    def availability(self, mu: float) -> float:
        return availability(mu, self.t_repair)

    def availability_at(self, mu):
        """Vectorized holder availability 1/(1 + mu*t_repair) (mu array-ok)."""
        return 1.0 / (1.0 + mu * self.t_repair)


class P2PCheckpointStore:
    """Per-event replica store for the heap reference simulator.

    Tracks individual holder deaths/repairs via
    :class:`ReplicaSetProcess` and accounts the server I/O each job
    imposes: checkpoint uploads when R=0 (server-only mode) and fallback
    restores when every peer replica is lost.
    """

    def __init__(self, spec: StoreSpec, mtbf_fn: Callable[[float], float],
                 rng: np.random.Generator, t0: float = 0.0,
                 mix: Optional["PeerClassMix"] = None,
                 shock: Optional["ShockSpec"] = None,
                 shock_clock: Optional["ShockClock"] = None):
        """``mix`` (a :class:`repro.sim.scenarios.PeerClassMix`) makes the
        holder fleet heterogeneous: holder slot classes come from the mix's
        deterministic assignment over the R slots, each class scales the
        holder hazard, and restores stripe over the *surviving* holders'
        class uplinks (DESIGN.md Sec 7).  This is the exact Poisson-binomial
        per-event oracle for the batched engine's mean-field law.

        ``shock`` subjects the holders to correlated mass-kill epochs
        (DESIGN.md Sec 8); pass the job network's ``shock_clock`` so
        replica losses coincide with the job failures that trigger
        restores — the correlation the engine's shock-mixture survivor law
        models in closed form.  Class scopes resolve through ``mix``.
        """
        self.spec = spec
        holder_mults = holder_ups = None
        if mix is not None and not mix.is_trivial and spec.R > 0:
            holder_mults = mix.hazard_mults(spec.R)
            holder_ups = mix.uplink_mults(spec.R)
        self._holder_ups = holder_ups
        scope_mask = (shock.scope_mask(mix, spec.R)
                      if shock is not None else None)
        self.holders = ReplicaSetProcess(spec.R, mtbf_fn, spec.t_repair,
                                         rng, t0=t0, slot_mults=holder_mults,
                                         shock=shock, shock_clock=shock_clock,
                                         scope_mask=scope_mask)
        self.server_bytes = 0.0
        self.n_server_restores = 0
        self.n_peer_restores = 0
        self._last_from_server = False
        self._last_td = 0.0

    def restore_seconds_at(self, t: float) -> float:
        """Endogenous T_d for a restore attempt starting at wall time ``t``.

        Reads the exact surviving replica count (and, for a class-aware
        store, exactly *which* holders survive — their class uplinks set
        the stripe bandwidth); the attempt's source and duration are
        remembered so :meth:`commit_restore` / :meth:`abort_restore` can
        account it per attempt.
        """
        if self._holder_ups is not None:
            alive = self.holders.alive_slots(t)
            self._last_from_server = not alive
            self._last_td = self.spec.transfer.restore_seconds_from(
                [self._holder_ups[i] for i in alive])
        else:
            m = self.holders.n_alive(t)
            self._last_from_server = m == 0
            self._last_td = self.spec.transfer.restore_seconds(m)
        return self._last_td

    def commit_restore(self) -> None:
        """The in-flight restore completed (no churn interrupted it)."""
        if self._last_from_server:
            self.n_server_restores += 1
            self.server_bytes += self.spec.transfer.img_bytes
        else:
            self.n_peer_restores += 1

    def abort_restore(self, elapsed: float) -> None:
        """The in-flight restore was interrupted by churn after ``elapsed``
        seconds.  A server-fallback attempt still moved elapsed/td of the
        image through the shared pipe — server I/O is billed per ATTEMPT,
        not per success, or heavy churn (where retries concentrate) would
        be exactly where the server load is undercounted."""
        if self._last_from_server and self._last_td > 0.0:
            frac = min(max(elapsed, 0.0) / self._last_td, 1.0)
            self.server_bytes += self.spec.transfer.img_bytes * frac

    def commit_checkpoint(self) -> None:
        """A checkpoint was written.  Server-only mode uploads the image to
        the work-pool server; with peer replicas the image goes to holders
        and costs the server nothing."""
        if self.spec.R == 0:
            self.server_bytes += self.spec.transfer.img_bytes
