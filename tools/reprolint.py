#!/usr/bin/env python
"""reprolint CLI — gate the repo's determinism/purity contracts.

    python tools/reprolint.py src tests benchmarks examples
    python tools/reprolint.py --json report.json src
    python tools/reprolint.py --list-rules

Exit code 1 when any non-suppressed, non-report-only finding survives;
0 on a clean tree.  Config: ``[tool.reprolint]`` in pyproject.toml.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
if str(_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(_ROOT / "src"))

from repro.analysis import RULES, LintConfig, lint_paths  # noqa: E402
from repro.analysis.report import render_human, render_json  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    default=["src", "tests", "benchmarks", "examples"],
                    help="files/directories to lint (relative to --root)")
    ap.add_argument("--root", default=str(_ROOT),
                    help="repo root (pyproject.toml location)")
    ap.add_argument("--json", metavar="FILE",
                    help="also write the machine-readable report here "
                         "('-' for stdout)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="list suppressed findings with their justifications")
    ap.add_argument("--list-rules", action="store_true",
                    help="print every rule, its summary and the invariant "
                         "it guards, then exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, rule in sorted(RULES.items()):
            gate = "report-only" if rule.severity == "info" else "gating"
            print(f"{rid} [{gate}] {rule.summary}")
            print(f"     guards: {rule.invariant}")
        return 0

    root = Path(args.root)
    report = lint_paths(args.paths or ["src"], root,
                        LintConfig.from_pyproject(root))
    # With --json - the JSON owns stdout; keep it parseable by moving the
    # human rendering to stderr.
    human_out = sys.stderr if args.json == "-" else sys.stdout
    render_human(report, human_out, show_suppressed=args.show_suppressed)
    if args.json == "-":
        render_json(report, sys.stdout)
    elif args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            render_json(report, fh)
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
